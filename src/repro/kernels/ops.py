"""jit'd dispatch wrappers around the Pallas kernels.

impl selection:
  "auto"      — Pallas on TPU, jnp oracle elsewhere (CPU container, dry-run)
  "pallas"    — compiled Pallas (TPU)
  "interpret" — Pallas interpret mode (CPU validation of the kernel body)
  "ref"       — pure-jnp oracle

`fused_xa_xtb` additionally panelizes the n2 axis so the kernel's xtb VMEM
window (n2_panel * k * 4B, double-buffered) stays under the budget.

Fallback telemetry: every budget-driven pallas->ref downgrade runs through
`_note_fallback`, which bumps a module counter (`kernel_fallbacks()`) and —
when a tracer is installed — emits a `kernel/fallback` instant carrying the
budget arithmetic.  Dispatch happens at Python trace time, so the telemetry
adds nothing to the compiled programs and the untraced build stays
bit-identical.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.sparse import BCSR
from repro.obs import trace as _obs
from repro.resilience import faults as _faults

from . import ref as _ref
from .bcsr_fused import bcsr_xa_xta as _bcsr_fused_pallas
from .bcsr_spmm import bcsr_spmm as _bcsr_pallas
from .flash_attention import flash_attention as _flash_pallas
from .fused_bilinear import fused_xa_xtb as _fused_pallas
from .mu_ratio import mu_update_a as _mu_pallas
from .policy import KernelPolicy, env_panel_bytes
from .score_topk import effective_pn as _effective_pn
from .score_topk import score_topk as _score_topk_pallas
from .score_topk import score_topk_stream as _score_topk_stream

__all__ = ["KernelPolicy", "VMEM_PANEL_BYTES", "kernel_fallbacks",
           "fused_xa_xtb", "mu_update_a", "bcsr_spmm", "bcsr_xa_xta",
           "flash_attention", "score_topk"]

# xtb window budget (pre double-buffer); RESCAL_VMEM_PANEL_BYTES overrides
# so CI can force the oracle fallback on any shard size.  KernelPolicy
# (kernels/policy.py, re-exported here as the public API surface) carries
# a per-policy override; this module constant is the process default.
VMEM_PANEL_BYTES = env_panel_bytes()

_n_fallbacks = 0


def kernel_fallbacks() -> int:
    """Process-lifetime count of budget-driven pallas->oracle fallbacks.
    The scheduler diffs this around each unit to attribute fallbacks."""
    return _n_fallbacks


def _note_fallback(kernel: str, requested_bytes: int, *,
                   chosen: str = "ref") -> None:
    global _n_fallbacks
    _n_fallbacks += 1
    _obs.event("kernel/fallback", kernel=kernel,
               requested_bytes=int(requested_bytes),
               budget_bytes=int(VMEM_PANEL_BYTES), chosen=chosen)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _dispatch(kernel: str, impl: str, *, cpu_impl: str = "ref") -> str:
    """Resolve `impl` ("auto" -> pallas on TPU, `cpu_impl` elsewhere) and
    probe the ONE kernel/dispatch fault seam.  A fired budget-overflow
    spec forces the documented oracle fallback — `_note_fallback`
    telemetry included — regardless of the real window arithmetic; the
    chaos drill uses this to exercise the fallback path end to end.
    Dispatch runs at Python trace time, so probes are per-compile and the
    no-plan path stays out of every compiled program."""
    if impl == "auto":
        impl = "pallas" if _on_tpu() else cpu_impl
    fired = _faults.fire("kernel/dispatch", kernel=kernel, impl=impl)
    if fired == "budget-overflow":
        _note_fallback(kernel, VMEM_PANEL_BYTES + 1, chosen=cpu_impl)
        impl = cpu_impl
    return impl


def _largest_tile(n: int, cap: int) -> int:
    """Largest divisor of n that is <= cap (the kernel requires exact
    tiling of both X axes)."""
    for t in range(min(cap, n), 0, -1):
        if n % t == 0:
            return t
    return 1


def fused_xa_xtb(X, B1, B2, *, impl: str = "auto", bm: int = 256,
                 bn: int = 256):
    """One-pass (X_t @ B1, X_t^T @ B2_t).  X: (m, n1, n2)."""
    impl = _dispatch("fused_xa_xtb", impl)
    if impl == "ref":
        return _ref.ref_fused_xa_xtb(X, B1, B2)
    interpret = impl == "interpret"
    m, n1, n2 = X.shape
    k = B1.shape[1]
    # shrink the requested tiles to exact divisors of the shard sides;
    # distributed shards (n/grid) are not generally 256-multiples
    bm = _largest_tile(n1, bm)
    bn = _largest_tile(n2, bn)
    if impl == "pallas" and min(bm, bn) < 8:
        # degenerate tiling (e.g. prime shard side) loses MXU sublane
        # alignment — the jnp oracle beats a 1-wide pallas grid
        return _ref.ref_fused_xa_xtb(X, B1, B2)
    panel = max(bn, (VMEM_PANEL_BYTES // max(k * 4, 1)) // bn * bn)
    if n2 <= panel:
        return _fused_pallas(X, B1, B2, bm=bm, bn=bn, interpret=interpret)
    # panelize columns: XA sums partials, XTB concatenates panels
    xa = jnp.zeros((m, n1, k), X.dtype)
    xtb_panels = []
    for c0 in range(0, n2, panel):
        Xp = jax.lax.slice_in_dim(X, c0, c0 + panel, axis=2)
        B1p = jax.lax.slice_in_dim(B1, c0, c0 + panel, axis=0)
        xa_p, xtb_p = _fused_pallas(Xp, B1p, B2, bm=bm, bn=bn,
                                    interpret=interpret)
        xa = xa + xa_p
        xtb_panels.append(xtb_p)
    return xa, jnp.concatenate(xtb_panels, axis=1)


def mu_update_a(A, Num, S, eps: float = 1e-16, *, impl: str = "auto",
                bm: int = 512):
    impl = _dispatch("mu_update_a", impl)
    if impl == "ref":
        return _ref.ref_mu_update_a(A, Num, S, eps)
    return _mu_pallas(A, Num, S, eps, bm=bm, interpret=impl == "interpret")


def _panel_bytes(sp: BCSR, k: int, dtype, n_panels: int) -> int:
    """VMEM-resident bytes of the BCSR kernels' (nb, bs, k) output
    panel(s)."""
    return n_panels * sp.nblocks * sp.bs * k * jnp.dtype(dtype).itemsize


def _panel_overflow(sp: BCSR, k: int, dtype, n_panels: int) -> bool:
    """True when the BCSR kernels' VMEM-resident (nb, bs, k) output
    panel(s) exceed the panel budget (panelized outputs are a ROADMAP
    follow-on; until then the jnp oracle takes over)."""
    return _panel_bytes(sp, k, dtype, n_panels) > VMEM_PANEL_BYTES


def bcsr_spmm(sp: BCSR, B, *, impl: str = "auto"):
    impl = _dispatch("bcsr_spmm", impl)
    if impl == "pallas" and _panel_overflow(sp, B.shape[1], B.dtype, 1):
        _note_fallback("bcsr_spmm", _panel_bytes(sp, B.shape[1], B.dtype, 1))
        impl = "ref"
    if impl == "ref":
        return _ref.ref_bcsr_spmm(sp, B)
    return _bcsr_pallas(sp, B, interpret=impl == "interpret")


def bcsr_xa_xta(sp: BCSR, B1, B2, *, impl: str = "auto"):
    """One-pass (X @ B1, X^T @ B2) on a BCSR tensor, B1/B2 shared (n, k)
    — the sparse twin of `fused_xa_xtb` (kernels/bcsr_fused.py)."""
    impl = _dispatch("bcsr_xa_xta", impl)
    if impl == "pallas" and _panel_overflow(sp, B1.shape[1], B1.dtype, 2):
        _note_fallback("bcsr_xa_xta",
                       _panel_bytes(sp, B1.shape[1], B1.dtype, 2))
        impl = "ref"
    if impl == "ref":
        return _ref.ref_bcsr_xa_xta(sp, B1, B2)
    return _bcsr_fused_pallas(sp, B1, B2, interpret=impl == "interpret")


def _topk_window_bytes(b: int, k: int, topk: int, pn: int) -> int:
    """VMEM-resident window of the score_topk kernel per grid step: the
    (pn, k) A panel, the (b, pn) panel scores, and the two f32/i32
    (b, topk + pn) merge candidate planes."""
    return 4 * (pn * k + b * pn + 2 * b * (topk + pn))


def score_topk(V, A, *, topk: int, impl: str = "auto",
               pn: int | None = None):
    """Batched top-k of V @ A^T without materializing (b, n).

    impl: auto      — pallas on TPU, panelized jnp stream elsewhere
          pallas    — compiled kernel (budget-gated; falls back to stream)
          interpret — kernel body on the CPU interpreter
          stream    — panelized jnp path (lax.scan, no (b, n) buffer)
          ref       — materializing oracle (ref.ref_score_topk)
    """
    from .score_topk import DEFAULT_PN
    pn = DEFAULT_PN if pn is None else pn
    impl = _dispatch("score_topk", impl, cpu_impl="stream")
    if impl == "ref":
        return _ref.ref_score_topk(V, A, topk)
    if impl == "stream":
        return _score_topk_stream(V, A, topk=topk, pn=pn)
    b, k = V.shape
    pn_eff = _effective_pn(A.shape[0], pn)
    window = _topk_window_bytes(b, k, topk, pn_eff)
    if impl == "pallas" and window > VMEM_PANEL_BYTES:
        _note_fallback("score_topk", window, chosen="stream")
        return _score_topk_stream(V, A, topk=topk, pn=pn)
    return _score_topk_pallas(V, A, topk=topk, pn=pn,
                              interpret=impl == "interpret")


def flash_attention(q, k, v, *, causal: bool = True, q_offset: int = 0,
                    sm_scale: float | None = None, impl: str = "auto",
                    bq: int = 256, bk: int = 256):
    impl = _dispatch("flash_attention", impl)
    # VMEM-resident window per q-tile: the (bq, d) accumulator plus the
    # streamed (bk, d) k/v tiles — gate against the shared panel budget
    # like the BCSR dispatchers (oversized heads fall back to the oracle)
    d = q.shape[-1]
    itemsize = jnp.dtype(q.dtype).itemsize
    window = (bq + 2 * bk) * d * itemsize
    if impl == "pallas" and window > VMEM_PANEL_BYTES:
        _note_fallback("flash_attention", window)
        impl = "ref"
    if impl == "ref":
        return _ref.ref_attention(q, k, v, causal=causal, q_offset=q_offset,
                                  sm_scale=sm_scale)
    return _flash_pallas(q, k, v, causal=causal, q_offset=q_offset,
                         sm_scale=sm_scale, bq=bq, bk=bk,
                         interpret=impl == "interpret")
