"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each ref_* implements the same contract as its kernel with plain einsums.
Tests sweep shapes x dtypes asserting allclose(kernel(interpret=True),
ref(...)); ops.py uses these as the CPU execution path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.sparse import BCSR, _pad_rows, spmm as _spmm


def ref_fused_xa_xtb(X: jax.Array, B1: jax.Array, B2: jax.Array):
    """X: (m, n1, n2), B1: (n2, k), B2: (m, n1, k)."""
    XA = jnp.einsum("mij,jk->mik", X, B1)
    XTB = jnp.einsum("mij,mik->mjk", X, B2)
    return XA, XTB


def ref_bcsr_xa_xta(sp: BCSR, B1: jax.Array, B2: jax.Array):
    """(X @ B1, X^T @ B2) for shared (n, k) operands — the single-pass
    contract of kernels/bcsr_fused.py expressed in jnp: both tile products
    are formed from one read of the stored blocks and reduced by ONE
    combined segment-sum (XA segments = block_rows, XTB segments =
    block_cols offset by nb), instead of the two independent
    spmm + spmm_t block sweeps."""
    m, nnzb, bs, _ = sp.data.shape
    nb = sp.nblocks
    n_pad = nb * bs
    k = B1.shape[1]
    if nnzb == 0:
        z = jnp.zeros((m, sp.n, k), B1.dtype)
        return z, z
    B1b = _pad_rows(B1, sp.n, n_pad).reshape(nb, bs, k)[sp.block_cols]
    B2b = _pad_rows(B2, sp.n, n_pad).reshape(nb, bs, k)[sp.block_rows]
    prod = jnp.concatenate(
        [jnp.einsum("mzab,zbk->mzak", sp.data, B1b),
         jnp.einsum("mzab,zak->mzbk", sp.data, B2b)], axis=1)
    segs = jnp.concatenate([sp.block_rows, sp.block_cols + nb])
    out = jax.ops.segment_sum(prod.swapaxes(0, 1), segs,
                              num_segments=2 * nb)      # (2nb, m, bs, k)
    out = out.transpose(1, 0, 2, 3).reshape(m, 2, n_pad, k)[:, :, :sp.n]
    return out[:, 0], out[:, 1]


def ref_mu_update_a(A: jax.Array, Num: jax.Array, S: jax.Array,
                    eps: float = 1e-16) -> jax.Array:
    return A * Num / (A @ S + eps)


def ref_bcsr_spmm(sp: BCSR, B: jax.Array) -> jax.Array:
    return _spmm(sp, B)


def ref_score_topk(V: jax.Array, A: jax.Array, topk: int):
    """The materializing oracle for kernels/score_topk.py: build the full
    (b, n) score matrix, then `lax.top_k` it.  Slots past n (topk > n)
    pad with (-inf, -1) to match the kernel contract."""
    scores = jnp.dot(V.astype(jnp.float32), A.astype(jnp.float32).T)
    b, n = scores.shape
    s, i = jax.lax.top_k(scores, min(topk, n))
    if topk > n:
        s = jnp.concatenate(
            [s, jnp.full((b, topk - n), -jnp.inf, s.dtype)], axis=1)
        i = jnp.concatenate(
            [i, jnp.full((b, topk - n), -1, jnp.int32)], axis=1)
    return s, i.astype(jnp.int32)


def ref_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, q_offset: int = 0,
                  sm_scale: float | None = None) -> jax.Array:
    """Exact softmax attention with GQA broadcast.  q: (b, hq, sq, d)."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    group = hq // hkv
    if sm_scale is None:
        sm_scale = 1.0 / (d ** 0.5)
    kq = jnp.repeat(k, group, axis=1)
    vq = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kq.astype(jnp.float32)) * sm_scale
    if causal:
        q_ids = q_offset + jnp.arange(sq)[:, None]
        k_ids = jnp.arange(skv)[None, :]
        s = jnp.where(q_ids >= k_ids, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vq.astype(jnp.float32)
                      ).astype(q.dtype)
