"""Panel-streaming top-k scoring kernel — the serve-tier hot spot.

A RESCAL link-prediction query `(s, r, ?)` scores every entity at once:

    scores = (A[s] @ R[r]) @ A^T          # one (n,)-wide row per query
    answer = top_k(scores)

(`(?, r, o)` is the same with R transposed.)  The engine batches queries
into V = A[anchor] @ R_q, so scoring is a (b, k) x (k, n) product whose
(b, n) result is immediately reduced to (b, topk).  At serving n (millions
of entities) that intermediate is the whole cost: materializing it to HBM
just to throw away all but k entries per row is pure waste.

This kernel streams A in (pn, k) row panels through VMEM — the same panel
discipline as `bcsr_fused` — and maintains the running top-k **inside**
the kernel: per grid step it scores one panel on the MXU, then merges the
(b, pn) panel scores into the resident (b, topk) best-so-far via `topk`
unrolled extract-max sweeps (max -> first-occurrence one-hot -> mask).
The (b, n) score matrix never exists in any memory space.

Tie-breaking matches `jax.lax.top_k` (equal scores -> lowest index
first): candidates are ordered [running | panel], the running buffer
inductively holds ties in ascending global index, and every panel element
has a larger global index than every running element, so first-occurrence
extraction preserves the global order.

`score_topk_stream` is the pure-jnp twin with identical semantics (a
`lax.scan` over the same panels, merged with `lax.top_k`) — it also never
materializes (b, n), and serves as the CPU execution path and the
dispatcher's fallback when the kernel's VMEM window would blow the panel
budget.  The materializing oracle lives in ref.py (`ref_score_topk`).

Outputs are always (f32 scores, i32 indices), both (b, topk), sorted by
descending score.  Rows past n (tail panels) and slots past n (topk > n)
come back as (-inf, -1).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.dist.compat import tpu_compiler_params

DEFAULT_PN = 2048
_LANE = 128


def effective_pn(n: int, pn: int = DEFAULT_PN) -> int:
    """Shrink the requested panel length to the lane-aligned cover of n
    (small vocabularies should not pay for a 2048-wide panel)."""
    return max(_LANE, min(pn, -(-n // _LANE) * _LANE))


def _merge_topk(cand_s, cand_i, topk: int):
    """Extract the top `topk` of the candidate columns, first-occurrence
    tie-break (== lowest candidate position).  Pure jnp, lowers inside
    the kernel (max/where/iota only — no cumsum, no sort)."""
    b, c = cand_s.shape
    pos = jax.lax.broadcasted_iota(jnp.int32, (b, c), 1)
    out_s, out_i = [], []
    for _ in range(topk):
        mx = jnp.max(cand_s, axis=1)
        eq = cand_s == mx[:, None]
        first_pos = jnp.min(jnp.where(eq, pos, c), axis=1)
        first = pos == first_pos[:, None]
        out_s.append(mx[:, None])
        # exactly one True per row; all-(-inf) rows pick candidate 0,
        # which is the running buffer's own (-inf, -1) padding slot
        out_i.append(jnp.sum(jnp.where(first, cand_i, 0), axis=1)[:, None])
        cand_s = jnp.where(first, -jnp.inf, cand_s)
    return (jnp.concatenate(out_s, axis=1),
            jnp.concatenate(out_i, axis=1))


def _kernel(v_ref, a_ref, s_ref, i_ref, *, n: int, pn: int, topk: int):
    p = pl.program_id(0)

    @pl.when(p == 0)
    def _():
        s_ref[...] = jnp.full_like(s_ref[...], -jnp.inf)
        i_ref[...] = jnp.full_like(i_ref[...], -1)

    v = v_ref[...]                                     # (b, k)
    a = a_ref[...]                                     # (pn, k)
    sp = jnp.dot(v, a.T, preferred_element_type=jnp.float32)   # (b, pn)
    b = sp.shape[0]
    gidx = p * pn + jax.lax.broadcasted_iota(jnp.int32, (b, pn), 1)
    sp = jnp.where(gidx < n, sp, -jnp.inf)             # mask the pad tail

    cand_s = jnp.concatenate([s_ref[...], sp], axis=1)
    cand_i = jnp.concatenate([i_ref[...], gidx], axis=1)
    new_s, new_i = _merge_topk(cand_s, cand_i, topk)
    s_ref[...] = new_s
    i_ref[...] = new_i


@functools.partial(jax.jit, static_argnames=("topk", "pn", "interpret"))
def score_topk(V: jax.Array, A: jax.Array, *, topk: int,
               pn: int = DEFAULT_PN, interpret: bool = False):
    """V: (b, k) query vectors, A: (n, k) entity factors
    -> (scores (b, topk) f32, indices (b, topk) i32), top-k of V @ A^T
    without materializing the (b, n) score matrix."""
    b, k = V.shape
    n = A.shape[0]
    pn = effective_pn(n, pn)
    n_panels = -(-n // pn)
    pad = n_panels * pn - n
    A_pad = jnp.pad(A, ((0, pad), (0, 0))) if pad else A

    scores, idx = pl.pallas_call(
        functools.partial(_kernel, n=n, pn=pn, topk=topk),
        grid=(n_panels,),
        in_specs=[
            pl.BlockSpec((b, k), lambda p: (0, 0)),
            pl.BlockSpec((pn, k), lambda p: (p, 0)),
        ],
        out_specs=[
            # constant index_map: the running top-k stays VMEM-resident
            # across the whole panel sweep (ops.score_topk budget-gates)
            pl.BlockSpec((b, topk), lambda p: (0, 0)),
            pl.BlockSpec((b, topk), lambda p: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, topk), jnp.float32),
            jax.ShapeDtypeStruct((b, topk), jnp.int32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
        name="score_topk",
    )(V.astype(jnp.float32), A_pad.astype(jnp.float32))
    return scores, idx


@functools.partial(jax.jit, static_argnames=("topk", "pn"))
def score_topk_stream(V: jax.Array, A: jax.Array, *, topk: int,
                      pn: int = DEFAULT_PN):
    """Pure-jnp panel stream with the kernel's exact semantics: a
    `lax.scan` over (pn, k) panels of A, running (b, topk) carry merged
    with `lax.top_k` over [running | panel] candidates.  Never builds the
    (b, n) score matrix — this is the production CPU path, not an oracle."""
    b, k = V.shape
    n = A.shape[0]
    pn = effective_pn(n, pn)
    n_panels = -(-n // pn)
    pad = n_panels * pn - n
    A_pad = jnp.pad(A, ((0, pad), (0, 0))) if pad else A
    panels = A_pad.astype(jnp.float32).reshape(n_panels, pn, k)
    Vf = V.astype(jnp.float32)
    base = jnp.arange(pn, dtype=jnp.int32)[None, :]

    def body(carry, xs):
        run_s, run_i = carry
        panel, p = xs
        sp = jnp.dot(Vf, panel.T, preferred_element_type=jnp.float32)
        gidx = jnp.broadcast_to(p * pn + base, sp.shape)
        sp = jnp.where(gidx < n, sp, -jnp.inf)
        cand_s = jnp.concatenate([run_s, sp], axis=1)
        cand_i = jnp.concatenate([run_i, gidx], axis=1)
        top_s, pos = jax.lax.top_k(cand_s, topk)
        top_i = jnp.take_along_axis(cand_i, pos, axis=1)
        return (top_s, top_i), None

    init = (jnp.full((b, topk), -jnp.inf, jnp.float32),
            jnp.full((b, topk), -1, jnp.int32))
    (run_s, run_i), _ = jax.lax.scan(
        body, init, (panels, jnp.arange(n_panels, dtype=jnp.int32)))
    return run_s, run_i
