"""Fused multiplicative-update ratio kernel (paper Eq. 2, A-row form).

Computes   A_out = A * Num / (A @ S + eps)   row-panel by row-panel,
fusing the (n, k) x (k, k) denominator matmul with the elementwise
multiply-ratio so the (n, k) denominator never round-trips through HBM.
XLA usually fuses the elementwise part but still materializes A @ S when it
feeds a multi-consumer graph (it does in the full MU step); this kernel
pins the whole update to one HBM read of A/Num and one write of A_out.

Blocking: grid (n // bm,); each step holds an (bm, k) panel of A and Num,
the full (k, k) S (k is the RESCAL rank — small), and writes one panel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.dist.compat import tpu_compiler_params

DEFAULT_BM = 512


def _kernel(a_ref, num_ref, s_ref, eps_ref, out_ref):
    a = a_ref[...]
    den = jnp.dot(a, s_ref[...], preferred_element_type=jnp.float32)
    out = a * num_ref[...] / (den.astype(a.dtype) + eps_ref[0])
    out_ref[...] = out


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def mu_update_a(A: jax.Array, Num: jax.Array, S: jax.Array,
                eps: float = 1e-16, *, bm: int = DEFAULT_BM,
                interpret: bool = False) -> jax.Array:
    """A, Num: (n, k); S: (k, k) -> A * Num / (A @ S + eps)."""
    n, k = A.shape
    bm = min(bm, n)
    assert n % bm == 0, (n, bm)
    eps_arr = jnp.full((1,), eps, A.dtype)
    return pl.pallas_call(
        _kernel,
        grid=(n // bm,),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i: (i, 0)),
            pl.BlockSpec((bm, k), lambda i: (i, 0)),
            pl.BlockSpec((k, k), lambda i: (0, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((bm, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, k), A.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
        name="mu_update_a",
    )(A, Num, S, eps_arr)
