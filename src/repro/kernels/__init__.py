"""Pallas TPU kernels for the compute hot spots, with pure-jnp oracles.

Kernels target TPU (pl.pallas_call + explicit BlockSpec VMEM tiling) and are
validated on CPU in interpret mode against ref.py.  ops.py is the public,
backend-dispatching API; KernelPolicy is the one knob bundle threaded
through configs and the serve engine.
"""
from . import ref
from .ops import (KernelPolicy, bcsr_spmm, bcsr_xa_xta, flash_attention,
                  fused_xa_xtb, mu_update_a, score_topk)

__all__ = ["KernelPolicy", "bcsr_spmm", "bcsr_xa_xta", "flash_attention",
           "fused_xa_xtb", "mu_update_a", "ref", "score_topk"]
