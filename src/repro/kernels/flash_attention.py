"""Flash (online-softmax) attention Pallas kernel — LM serving hot spot.

Blockwise attention with running max / normalizer so the (sq x skv) score
matrix never materializes in HBM; required for the 32k-prefill shapes and
the hybrid arch's global-attention layers.  GQA is handled by mapping each
query head to its KV group in the index maps (no KV head replication in
HBM).  Causal masking supports a query offset so the same kernel serves
both prefill (offset 0) and chunked/continuation prefill.

Grid (bh, iq, jk) = (batch * q_heads, sq / bq, skv / bk); scratch keeps the
running (m, l, acc) statistics in VMEM across the jk sweep; the output
window (bh, iq) is written once on the final jk step.

The pure-JAX chunked-attention in models/attention.py is the oracle and the
CPU/dry-run execution path (same math, XLA-scheduled).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.dist.compat import tpu_compiler_params

DEFAULT_BQ = 256
DEFAULT_BK = 256
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
            *, causal: bool, q_offset: int, sm_scale: float):
    iq = pl.program_id(1)
    jk = pl.program_id(2)
    njk = pl.num_programs(2)

    @pl.when(jk == 0)
    def _():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]                                       # (bq, d)
    k = k_ref[0]                                       # (bk, d)
    v = v_ref[0]                                       # (bk, d)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * sm_scale

    if causal:
        bq, bk = s.shape
        q_ids = q_offset + iq * bq + jax.lax.broadcasted_iota(
            jnp.int32, (bq, bk), 0)
        k_ids = jk * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(q_ids >= k_ids, s, NEG_INF)

    m_prev = m_scr[...]
    m_cur = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur)
    l_cur = alpha * l_scr[...] + p.sum(axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_scr[...] = m_cur
    l_scr[...] = l_cur

    @pl.when(jk == njk - 1)
    def _():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "q_offset", "bq", "bk", "interpret", "sm_scale"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, q_offset: int = 0,
                    sm_scale: float | None = None,
                    bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                    interpret: bool = False) -> jax.Array:
    """q: (b, hq, sq, d); k, v: (b, hkv, skv, d); hq % hkv == 0.
    Returns (b, hq, sq, d)."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    if sm_scale is None:
        sm_scale = 1.0 / (d ** 0.5)
    bq = min(bq, sq)
    bk = min(bk, skv)
    assert sq % bq == 0 and skv % bk == 0

    qf = q.reshape(b * hq, sq, d)
    kf = k.reshape(b * hkv, skv, d)
    vf = v.reshape(b * hkv, skv, d)

    def kv_map(bh, iq, jk):
        return ((bh // hq) * hkv + (bh % hq) // group, jk, 0)

    out = pl.pallas_call(
        functools.partial(_kernel, causal=causal, q_offset=q_offset,
                          sm_scale=sm_scale),
        grid=(b * hq, sq // bq, skv // bk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, iq, jk: (bh, iq, 0)),
            pl.BlockSpec((1, bk, d), kv_map),
            pl.BlockSpec((1, bk, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, iq, jk: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="flash_attention",
    )(qf, kf, vf)
    return out.reshape(b, hq, sq, d)
