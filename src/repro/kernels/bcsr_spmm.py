"""Block-sparse SpMM Pallas kernel — the TPU adaptation of the paper's
CSR sparse path (DESIGN.md §2, "Sparse = block-sparse").

Computes  out_t = X_t @ B  where X is a BCSR tensor (core/sparse.py):
MXU-aligned (bs x bs) stored blocks with row/col coordinates sorted
row-major.  The coordinate lists ride in scalar-prefetch SMEM so the block
index maps can chase them (the canonical Pallas sparse pattern); compute
scales with the number of *stored* blocks, recovering the paper's
O(m * delta * n^2 * k) sparse bound on hardware that hates gather/scatter.

Grid: (m, nnzb).  Per step (t, z):
    data : (bs, bs)     stored block z of slice t
    b    : (bs, k)      row-block `cols[z]` of B    (gathered via prefetch)
    out  : (nb, bs, k)  full output panel of slice t, zeroed at z == 0;
                        row `rows[z]` accumulates the tile product

The panel-resident output (window constant per t, so revisits are
consecutive) is what makes the empty-block-row guarantee KERNEL-side:
block-rows that own no stored block come out exact zero, with no
"every block-row stores >= 1 block" precondition — the soundness contract
io.partition's front-padded shards rely on (ISSUE 5; the per-row
(bs, k)-window variant this replaces left untouched rows undefined).
VMEM: the panel costs nb * bs * k * itemsize; ops.py falls back to the
jnp oracle past the panel budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.dist.compat import tpu_compiler_params

from repro.core.sparse import BCSR


def _kernel(rows_ref, cols_ref, data_ref, b_ref, out_ref):
    z = pl.program_id(1)

    # new slice t: zero the resident panel BEFORE the first accumulate, so
    # block-rows with no stored block yield exact-zero output rows
    @pl.when(z == 0)
    def _():
        out_ref[0] = jnp.zeros_like(out_ref[0])

    part = jnp.dot(data_ref[0, 0], b_ref[0],
                   preferred_element_type=jnp.float32)
    # leading dims indexed with ds(start, 1), not bare ints: integer
    # indices in pl.load/store tuples are rejected by older pallas
    idx = (pl.ds(0, 1), pl.ds(rows_ref[z], 1), slice(None), slice(None))
    pl.store(out_ref, idx, pl.load(out_ref, idx)
             + part[None, None].astype(out_ref.dtype))


@functools.partial(jax.jit, static_argnames=("interpret",))
def bcsr_spmm(sp: BCSR, B: jax.Array, *, interpret: bool = False
              ) -> jax.Array:
    """sp: BCSR (m, nnzb, bs, bs) with row-major-sorted blocks; B: (n, k)
    -> (m, n, k).

    Ingest edge cases (ISSUE 3): an empty pattern short-circuits to zeros
    (a 0-sized grid axis is invalid), and a logical n that the block size
    does not divide is handled by zero-padding B's entity axis to the
    blocked extent and cropping the output back — the stored tail blocks
    are already zero-masked by construction (core/sparse.py).
    """
    m, nnzb, bs, _ = sp.data.shape
    nb = sp.nblocks
    k = B.shape[1]
    if nnzb == 0:
        return jnp.zeros((m, sp.n, k), B.dtype)
    if nb * bs != sp.n:
        B = jnp.pad(B, ((0, nb * bs - sp.n), (0, 0)))
    Bb = B.reshape(nb, bs, k)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(m, nnzb),
        in_specs=[
            pl.BlockSpec((1, 1, bs, bs), lambda t, z, rows, cols: (t, z, 0, 0)),
            pl.BlockSpec((1, bs, k), lambda t, z, rows, cols: (cols[z], 0, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, nb, bs, k), lambda t, z, rows, cols: (t, 0, 0, 0)),
    )
    out = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, nb, bs, k), B.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
        name="bcsr_spmm",
    )(sp.block_rows, sp.block_cols, sp.data, Bb)
    return out.reshape(m, nb * bs, k)[:, :sp.n]
