"""KernelPolicy — the one knob bundle for kernel dispatch.

Before PR 9 every layer grew its own pair of kernel switches
(``use_fused_kernel`` / ``fused_impl`` on the configs, ``use_fused`` /
``impl`` on the sparse ops) plus the ``RESCAL_VMEM_PANEL_BYTES`` env
override.  ``KernelPolicy`` unifies them into a single frozen (hence
jit-static-safe) dataclass that travels through ``RescalkConfig``,
``DistRescalConfig``, ``core.sparse`` and the serve engine.

The legacy kwargs stay accepted for one release as deprecated aliases
(``KernelPolicy.resolve`` merges them; tests/test_serve.py asserts they
still resolve).  This module is deliberately stdlib-only so the numpy-only
``selection/types.py`` and scripts can reference it without importing jax.
"""
from __future__ import annotations

import dataclasses
import os

IMPLS = ("auto", "pallas", "interpret", "ref", "stream")

# Default VMEM panel budget (bytes) when the env override is absent; kept
# here (stdlib-only) so ops.py and scripts share one source of truth.
DEFAULT_PANEL_BYTES = 4 * 1024 * 1024


def env_panel_bytes() -> int:
    """Panel budget honoring the RESCAL_VMEM_PANEL_BYTES env override."""
    return int(os.environ.get("RESCAL_VMEM_PANEL_BYTES",
                              DEFAULT_PANEL_BYTES))


@dataclasses.dataclass(frozen=True)
class KernelPolicy:
    """How the sparse/serve ops pick a kernel implementation.

    use_fused    route the MU products through the fused Pallas kernels
                 (previously ``use_fused_kernel`` / ``use_fused``)
    impl         auto|pallas|interpret|ref|stream (previously
                 ``fused_impl`` / ``impl``); "stream" is the panelized
                 jnp path (serve scoring only)
    panel_bytes  VMEM panel budget override; None = honor the
                 RESCAL_VMEM_PANEL_BYTES env var (ops.VMEM_PANEL_BYTES)
    """
    use_fused: bool = False
    impl: str = "auto"
    panel_bytes: int | None = None

    def __post_init__(self):
        if self.impl not in IMPLS:
            raise ValueError(f"impl must be one of {IMPLS}, "
                             f"got {self.impl!r}")

    @property
    def budget_bytes(self) -> int:
        return self.panel_bytes if self.panel_bytes is not None \
            else env_panel_bytes()

    @classmethod
    def resolve(cls, policy: "KernelPolicy | None" = None, *,
                use_fused: bool | None = None,
                impl: str | None = None) -> "KernelPolicy":
        """Merge a new-style policy with the deprecated per-call kwargs.

        The aliases only apply when no policy is given; passing both is an
        error so callers can't silently disagree with themselves.
        """
        if policy is not None:
            if use_fused is not None or impl is not None:
                raise TypeError(
                    "pass either policy= or the deprecated "
                    "use_fused=/impl= aliases, not both")
            return policy
        return cls(use_fused=bool(use_fused) if use_fused is not None
                   else False,
                   impl=impl if impl is not None else "auto")
