"""Fused dual-product bilinear kernel — the RESCAL A-update hot spot.

For every relation slice t the A-update numerator (paper Alg. 3 lines
10-14) needs BOTH products of the local data block X_t:

    XA_t   = X_t   @ B1        (B1 = A^(j),    shared over t)
    XTB_t  = X_t^T @ B2_t      (B2_t = A R_t,  per slice)

A naive implementation streams X from HBM twice.  X is by far the largest
operand (n_loc^2 * m vs n_loc * k factors), so at RESCAL shapes the memory
roofline term is ~2 * bytes(X); this kernel tiles X through VMEM **once**
and emits both partial products, halving the dominant HBM term
(beyond-paper optimization #2, EXPERIMENTS.md §Perf).

Blocking (per grid step (t, i, j)):
    x    : (bm, bn)   VMEM tile of X_t
    b1   : (bn, k)    column-block of B1          (revisited over i)
    b2   : (bm, k)    row-block of B2_t           (revisited over j)
    xa   : (bm, k)    out row-panel, accumulated over j (consecutive)
    xtb  : (n2, k)    out full panel, accumulated over (i, j); its window is
                      constant per t so revisits are consecutive.

The MXU sees two (bm x bn) @ (bn x k) contractions per tile; bm = bn = 256
keeps the X tile at 256 KB and both matmul operands 128-aligned.
ops.fused_xa_xtb() panelizes n2 when n2 * k * 4B would exceed the VMEM
budget for the xtb window.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.dist.compat import tpu_compiler_params

DEFAULT_BM = 256
DEFAULT_BN = 256


def _kernel(x_ref, b1_ref, b2_ref, xa_ref, xtb_ref):
    i = pl.program_id(1)
    j = pl.program_id(2)
    nj = pl.num_programs(2)

    x = x_ref[0]                                   # (bm, bn)
    b1 = b1_ref[...]                               # (bn, k)
    b2 = b2_ref[0]                                 # (bm, k)

    # ---- XA row panel: init on first column block, then accumulate ----
    part_xa = jnp.dot(x, b1, preferred_element_type=jnp.float32)

    @pl.when(j == 0)
    def _():
        xa_ref[0] = part_xa.astype(xa_ref.dtype)

    @pl.when(j != 0)
    def _():
        xa_ref[0] += part_xa.astype(xa_ref.dtype)

    # ---- XTB full panel: zero once per t, accumulate the (j) slice ----
    @pl.when((i == 0) & (j == 0))
    def _():
        xtb_ref[0] = jnp.zeros_like(xtb_ref[0])

    part_xtb = jnp.dot(x.T, b2, preferred_element_type=jnp.float32)
    bn = x.shape[1]
    # leading dim indexed with ds(0, 1), not a bare int: integer indices in
    # pl.load/store tuples are rejected by older pallas releases
    idx = (pl.ds(0, 1), pl.ds(j * bn, bn), slice(None))
    cur = pl.load(xtb_ref, idx)
    pl.store(xtb_ref, idx,
             cur + part_xtb[None].astype(xtb_ref.dtype))
    del nj


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def fused_xa_xtb(X: jax.Array, B1: jax.Array, B2: jax.Array,
                 *, bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                 interpret: bool = False):
    """X: (m, n1, n2), B1: (n2, k), B2: (m, n1, k)
    -> (XA: (m, n1, k), XTB: (m, n2, k)), reading X once."""
    m, n1, n2 = X.shape
    k = B1.shape[1]
    bm = min(bm, n1)
    bn = min(bn, n2)
    assert n1 % bm == 0 and n2 % bn == 0, (n1, n2, bm, bn)
    grid = (m, n1 // bm, n2 // bn)

    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bn), lambda t, i, j: (t, i, j)),
            pl.BlockSpec((bn, k), lambda t, i, j: (j, 0)),
            pl.BlockSpec((1, bm, k), lambda t, i, j: (t, i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bm, k), lambda t, i, j: (t, i, 0)),
            pl.BlockSpec((1, n2, k), lambda t, i, j: (t, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n1, k), X.dtype),
            jax.ShapeDtypeStruct((m, n2, k), X.dtype),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary")),
        interpret=interpret,
        name="fused_xa_xtb",
    )(X, B1, B2)
