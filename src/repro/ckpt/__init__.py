"""Atomic, grid-agnostic checkpointing (elastic restore)."""
from .checkpoint import (atomic_json_dump, latest_step, restore,
                         save, save_async)

__all__ = ["atomic_json_dump", "latest_step", "restore", "save",
           "save_async"]
