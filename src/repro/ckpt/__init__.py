"""Atomic, digest-verified, self-healing checkpointing (elastic restore)."""
from .checkpoint import (AsyncSave, CheckpointError, atomic_json_dump,
                         latest_step, restore, save, save_async,
                         verify_step)

__all__ = ["AsyncSave", "CheckpointError", "atomic_json_dump",
           "latest_step", "restore", "save", "save_async", "verify_step"]
