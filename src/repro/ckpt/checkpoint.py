"""Grid-agnostic checkpointing with atomic writes, digests, and self-healing.

Checkpoints store every leaf in *global* layout (device_get assembles the
global array regardless of the mesh it lived on), keyed by its tree path.
Restoring onto a different mesh — or a different mesh *shape* after an
elastic resize — is therefore a plain `device_put` with the new shardings:
partitioning is pure block-slicing, exactly the property DESIGN.md §4
relies on for fault tolerance.

Layout on disk:
    <dir>/step_<n>.npz        one array per flattened tree path
    <dir>/step_<n>.json       manifest: step, paths, shapes, dtypes,
                              per-leaf sha256 digests
    <dir>/LATEST              text file with the newest step number

Writes are atomic (tmp file + os.replace) so a crash mid-save never
corrupts the restore point; digests make the weaker failures — torn
multi-file writes (npz replaced, manifest not), bit rot, truncation —
*detectable*, and `restore` makes them *survivable*: a step that fails
verification is quarantined (renamed `step_<n>.corrupt.*`, with a
`ckpt/quarantine` trace event) and restore falls back through older
steps to the newest verifiable one instead of crashing.

`save_async` moves serialization off the training thread (device_get
happens synchronously to snapshot the values, the file write happens in
the background) and returns an :class:`AsyncSave` handle whose
``join()``/``result()`` re-raise any background-write failure — a failed
save can no longer silently age the restore point.
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import threading
import warnings
from typing import Any

import jax
import numpy as np

from repro.obs import trace as obs
from repro.resilience import faults

_STEP_MANIFEST = re.compile(r"^step_(\d+)\.json$")


class CheckpointError(RuntimeError):
    """A checkpoint failed to load or verify.  Unlike the bare asserts it
    replaces, this survives `python -O` and carries the reason."""


def atomic_json_dump(path: str, obj, **json_kwargs) -> str:
    """Write JSON with the same crash-safe discipline as the checkpoint
    files (tmp file + os.replace).  Shared by every JSON artifact writer
    (selection reports, sweep-config fingerprints)."""
    parent = os.path.dirname(path)
    if parent:
        os.makedirs(parent, exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(obj, f, **json_kwargs)
    os.replace(tmp, path)
    return path


def _flatten(tree) -> dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = np.asarray(jax.device_get(leaf))
    return out


def _leaf_digest(arr: np.ndarray) -> str:
    return hashlib.sha256(np.ascontiguousarray(arr).tobytes()).hexdigest()


def _manifest(step: int, arrays: dict[str, np.ndarray]) -> dict:
    return {"step": step,
            "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype),
                           "sha256": _leaf_digest(v)}
                       for k, v in arrays.items()}}


def _write_step(ckpt_dir: str, step: int,
                arrays: dict[str, np.ndarray]) -> str:
    """The ONE step writer behind save/save_async: npz then manifest then
    LATEST, each via tmp + os.replace so every prefix of a crash leaves a
    coherent (verifiable or absent) step behind."""
    os.makedirs(ckpt_dir, exist_ok=True)
    base = os.path.join(ckpt_dir, f"step_{step}")
    with open(base + ".npz.tmp", "wb") as f:
        np.savez(f, **arrays)
    with open(base + ".json.tmp", "w") as f:
        json.dump(_manifest(step, arrays), f)
    os.replace(base + ".npz.tmp", base + ".npz")
    os.replace(base + ".json.tmp", base + ".json")
    tmp_latest = os.path.join(ckpt_dir, "LATEST.tmp")
    with open(tmp_latest, "w") as f:
        f.write(str(step))
    os.replace(tmp_latest, os.path.join(ckpt_dir, "LATEST"))
    faults.fire("ckpt/write", path=base + ".npz", step=step)
    return base + ".npz"


def save(ckpt_dir: str, step: int, tree) -> str:
    return _write_step(ckpt_dir, step, _flatten(tree))


class AsyncSave:
    """Handle for a background checkpoint write.  The write thread parks
    its exception here; ``join()``/``result()`` re-raise it so callers
    surface failed saves at the next checkpoint boundary instead of
    silently aging their restore point."""

    def __init__(self, ckpt_dir: str, step: int,
                 arrays: dict[str, np.ndarray]):
        self.step = step
        self._path: str | None = None
        self._error: BaseException | None = None

        def _write():
            try:
                self._path = _write_step(ckpt_dir, step, arrays)
            except BaseException as err:    # noqa: BLE001 — re-raised in join
                self._error = err

        self._thread = threading.Thread(target=_write, daemon=True,
                                        name=f"ckpt-save-{step}")
        self._thread.start()

    def done(self) -> bool:
        return not self._thread.is_alive()

    def join(self, timeout: float | None = None) -> None:
        """Wait for the write; re-raise any background failure."""
        self._thread.join(timeout)
        if self._error is not None:
            raise CheckpointError(
                f"async save of step {self.step} failed: "
                f"{self._error}") from self._error

    def result(self, timeout: float | None = None) -> str:
        """join() and return the written npz path."""
        self.join(timeout)
        if self._thread.is_alive():
            raise TimeoutError(f"async save of step {self.step} still "
                               f"running after {timeout}s")
        assert self._path is not None
        return self._path


def save_async(ckpt_dir: str, step: int, tree) -> AsyncSave:
    """Snapshot now (device_get), write in the background.  The returned
    handle's join()/result() re-raise background-write failures."""
    return AsyncSave(ckpt_dir, step, _flatten(tree))


def _scan_steps(ckpt_dir: str) -> list[int]:
    """Newest-first step numbers with a manifest on disk (quarantined
    `step_*.corrupt.json` files do not match)."""
    try:
        names = os.listdir(ckpt_dir)
    except FileNotFoundError:
        return []
    steps = [int(m.group(1)) for name in names
             if (m := _STEP_MANIFEST.match(name))]
    return sorted(steps, reverse=True)


def latest_step(ckpt_dir: str) -> int | None:
    path = os.path.join(ckpt_dir, "LATEST")
    if os.path.exists(path):
        try:
            with open(path) as f:
                text = f.read().strip()
            if text:
                return int(text)
            raise ValueError("empty LATEST")
        except (OSError, ValueError) as err:
            warnings.warn(f"unreadable LATEST in {ckpt_dir} ({err}); "
                          f"scanning step manifests instead",
                          stacklevel=2)
    steps = _scan_steps(ckpt_dir)
    return steps[0] if steps else None


def verify_step(ckpt_dir: str, step: int) -> bool:
    """True iff step `step` loads and every leaf matches its manifest
    entry (shape, dtype, sha256)."""
    try:
        _load_step(ckpt_dir, step)
        return True
    except CheckpointError:
        return False


def _load_step(ckpt_dir: str, step: int) -> dict[str, np.ndarray]:
    """Load + verify one step against its manifest.  Raises
    CheckpointError on any inconsistency: missing/torn files, leaf-set
    mismatch (the kill-between-replace signature), shape/dtype drift,
    digest mismatch.  Pre-digest manifests verify shape/dtype only."""
    base = os.path.join(ckpt_dir, f"step_{step}")
    try:
        with open(base + ".json") as f:
            manifest = json.load(f)
    except (OSError, json.JSONDecodeError) as err:
        raise CheckpointError(f"step {step}: bad manifest: {err}") from err
    try:
        with np.load(base + ".npz", allow_pickle=False) as data:
            arrays = {k: data[k] for k in data.files}
    except Exception as err:
        raise CheckpointError(f"step {step}: bad npz: {err}") from err
    leaves = manifest.get("leaves", {})
    if set(arrays) != set(leaves):
        raise CheckpointError(
            f"step {step}: npz/manifest leaf sets differ "
            f"(npz-only={sorted(set(arrays) - set(leaves))}, "
            f"manifest-only={sorted(set(leaves) - set(arrays))})")
    for key, meta in leaves.items():
        arr = arrays[key]
        if list(arr.shape) != list(meta["shape"]):
            raise CheckpointError(f"step {step}: leaf {key!r} shape "
                                  f"{list(arr.shape)} != manifest "
                                  f"{meta['shape']}")
        want = meta.get("sha256")
        if want is not None and _leaf_digest(arr) != want:
            raise CheckpointError(f"step {step}: leaf {key!r} sha256 "
                                  f"mismatch (corrupt bytes?)")
    return arrays


def _quarantine(ckpt_dir: str, step: int, reason: str) -> None:
    """Rename a bad step out of the restore path and record it."""
    base = os.path.join(ckpt_dir, f"step_{step}")
    moved = []
    for ext in (".npz", ".json"):
        src = base + ext
        if os.path.exists(src):
            os.replace(src, f"{base}.corrupt{ext}")
            moved.append(ext)
    warnings.warn(f"quarantined checkpoint step {step} in {ckpt_dir}: "
                  f"{reason}", stacklevel=3)
    obs.event("ckpt/quarantine", step=step, reason=reason,
              files=len(moved))


def restore(ckpt_dir: str, like, step: int | None = None,
            shardings=None) -> tuple[Any, int]:
    """Restore into the structure of `like` (a pytree of arrays or
    ShapeDtypeStructs).  `shardings`, if given, is a matching pytree of
    NamedSharding — this is the elastic-reshard path.

    Self-healing: a step that fails verification is quarantined
    (`step_<n>.corrupt.*` + `ckpt/quarantine` event) and restore falls
    back through older steps to the newest verifiable one; only when no
    step survives does it raise.  A structure mismatch against `like`
    is a caller error, not corruption — it raises without quarantine.
    """
    newest = latest_step(ckpt_dir)
    if newest is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    candidates = sorted({newest, *_scan_steps(ckpt_dir)}, reverse=True)
    if step is not None:
        candidates = [s for s in candidates if s <= step]
        if not candidates:
            raise CheckpointError(f"no checkpoint step <= {step} "
                                  f"in {ckpt_dir}")
    healed = False
    for s in candidates:
        faults.fire("ckpt/read", path=os.path.join(ckpt_dir,
                                                   f"step_{s}.npz"),
                    step=s)
        try:
            arrays = _load_step(ckpt_dir, s)
        except CheckpointError as err:
            _quarantine(ckpt_dir, s, str(err))
            healed = True
            continue
        tree = _assemble(arrays, like, s)
        if shardings is not None:
            tree = jax.device_put(tree, shardings)
        if healed:      # LATEST pointed at a quarantined step — repoint it
            tmp = os.path.join(ckpt_dir, "LATEST.tmp")
            with open(tmp, "w") as f:
                f.write(str(s))
            os.replace(tmp, os.path.join(ckpt_dir, "LATEST"))
        return tree, s
    raise CheckpointError(f"no verifiable checkpoint step in {ckpt_dir} "
                          f"({len(candidates)} candidate(s) quarantined)")


def _assemble(arrays: dict[str, np.ndarray], like, step: int):
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        if key not in arrays:
            raise CheckpointError(f"step {step}: leaf {key!r} missing "
                                  f"from checkpoint (have "
                                  f"{sorted(arrays)})")
        arr = arrays[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise CheckpointError(f"step {step}: leaf {key!r} shape "
                                  f"{tuple(arr.shape)} != restore target "
                                  f"{tuple(leaf.shape)}")
        want = np.dtype(leaf.dtype)
        if arr.dtype != want:
            if arr.dtype.kind == "V" and arr.dtype.itemsize == want.itemsize:
                # npz stores ml_dtypes (bf16, fp8) as raw void — view back
                arr = arr.view(want)
            else:
                arr = arr.astype(want)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)
