"""Grid-agnostic checkpointing with atomic writes and elastic restore.

Checkpoints store every leaf in *global* layout (device_get assembles the
global array regardless of the mesh it lived on), keyed by its tree path.
Restoring onto a different mesh — or a different mesh *shape* after an
elastic resize — is therefore a plain `device_put` with the new shardings:
partitioning is pure block-slicing, exactly the property DESIGN.md §4
relies on for fault tolerance.

Layout on disk:
    <dir>/step_<n>.npz        one array per flattened tree path
    <dir>/step_<n>.json       manifest: step, paths, shapes, dtypes
    <dir>/LATEST              text file with the newest step number

Writes are atomic (tmp file + os.replace) so a crash mid-save never
corrupts the restore point.  `save_async` moves serialization off the
training thread (device_get happens synchronously to snapshot the values,
the file write happens in the background).
"""
from __future__ import annotations

import json
import os
import threading
from typing import Any

import jax
import numpy as np


def atomic_json_dump(path: str, obj, **json_kwargs) -> str:
    """Write JSON with the same crash-safe discipline as the checkpoint
    files (tmp file + os.replace).  Shared by every JSON artifact writer
    (selection reports, sweep-config fingerprints)."""
    parent = os.path.dirname(path)
    if parent:
        os.makedirs(parent, exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(obj, f, **json_kwargs)
    os.replace(tmp, path)
    return path


def _flatten(tree) -> dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = np.asarray(jax.device_get(leaf))
    return out


def save(ckpt_dir: str, step: int, tree) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    arrays = _flatten(tree)
    manifest = {"step": step,
                "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                           for k, v in arrays.items()}}
    base = os.path.join(ckpt_dir, f"step_{step}")
    tmp_npz, tmp_json = base + ".npz.tmp", base + ".json.tmp"
    with open(tmp_npz, "wb") as f:
        np.savez(f, **arrays)
    with open(tmp_json, "w") as f:
        json.dump(manifest, f)
    os.replace(tmp_npz, base + ".npz")
    os.replace(tmp_json, base + ".json")
    tmp_latest = os.path.join(ckpt_dir, "LATEST.tmp")
    with open(tmp_latest, "w") as f:
        f.write(str(step))
    os.replace(tmp_latest, os.path.join(ckpt_dir, "LATEST"))
    return base + ".npz"


def save_async(ckpt_dir: str, step: int, tree) -> threading.Thread:
    """Snapshot now (device_get), write in the background."""
    arrays = _flatten(tree)   # synchronous snapshot

    def _write():
        os.makedirs(ckpt_dir, exist_ok=True)
        manifest = {"step": step,
                    "leaves": {k: {"shape": list(v.shape),
                                   "dtype": str(v.dtype)}
                               for k, v in arrays.items()}}
        base = os.path.join(ckpt_dir, f"step_{step}")
        with open(base + ".npz.tmp", "wb") as f:
            np.savez(f, **arrays)
        with open(base + ".json.tmp", "w") as f:
            json.dump(manifest, f)
        os.replace(base + ".npz.tmp", base + ".npz")
        os.replace(base + ".json.tmp", base + ".json")
        with open(os.path.join(ckpt_dir, "LATEST.tmp"), "w") as f:
            f.write(str(step))
        os.replace(os.path.join(ckpt_dir, "LATEST.tmp"),
                   os.path.join(ckpt_dir, "LATEST"))

    t = threading.Thread(target=_write, daemon=True)
    t.start()
    return t


def latest_step(ckpt_dir: str) -> int | None:
    path = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return int(f.read().strip())


def restore(ckpt_dir: str, like, step: int | None = None,
            shardings=None) -> tuple[Any, int]:
    """Restore into the structure of `like` (a pytree of arrays or
    ShapeDtypeStructs).  `shardings`, if given, is a matching pytree of
    NamedSharding — this is the elastic-reshard path."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    data = np.load(os.path.join(ckpt_dir, f"step_{step}.npz"))

    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = data[key]
        assert tuple(arr.shape) == tuple(leaf.shape), (key, arr.shape,
                                                       leaf.shape)
        want = np.dtype(leaf.dtype)
        if arr.dtype != want:
            if arr.dtype.kind == "V" and arr.dtype.itemsize == want.itemsize:
                # npz stores ml_dtypes (bf16, fp8) as raw void — view back
                arr = arr.view(want)
            else:
                arr = arr.astype(want)
        leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.device_put(tree, shardings)
    return tree, step
