"""FactorBundle — the versioned on-disk artifact for swept RESCAL factors.

Layout (one directory):

    bundle.json    format_version, shapes, sha1 digest of the factor
                   bytes, optional vocab (entities/relations in id order),
                   optional training-operand manifest fingerprint, meta
                   (k_opt, criterion, rel_err, ...)
    factors.npz    A (n, k) f32, R (m, k, k) f32, optional permutation
                   (the BlockPartition row order A lives in)

Both files are written with the checkpoint layer's crash-safe discipline
(tmp + os.replace).  `load` re-derives the digest and refuses factors that
do not match their manifest — `scripts/check_trace.py` runs the same
validation (standalone, stdlib+numpy) on the report's bundle pointer.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os

import numpy as np

from repro.ckpt import atomic_json_dump

FORMAT_VERSION = 1
ARRAYS_NAME = "factors.npz"
MANIFEST_NAME = "bundle.json"


class BundleError(Exception):
    """Missing/malformed/corrupt bundle artifact."""


def _digest(A: np.ndarray, R: np.ndarray) -> str:
    h = hashlib.sha1()
    for arr in (A, R):
        h.update(str(arr.shape).encode())
        h.update(np.ascontiguousarray(arr).tobytes())
    return h.hexdigest()


@dataclasses.dataclass
class FactorBundle:
    A: np.ndarray                              # (n, k) float32
    R: np.ndarray                              # (m, k, k) float32
    entities: list[str] | None = None          # vocab, id order
    relations: list[str] | None = None
    permutation: np.ndarray | None = None      # BlockPartition row perm
    manifest: dict | None = None               # training-operand fingerprint
    meta: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        self.A = np.ascontiguousarray(self.A, dtype=np.float32)
        self.R = np.ascontiguousarray(self.R, dtype=np.float32)
        if self.A.ndim != 2 or self.R.ndim != 3 or \
                self.R.shape[1] != self.R.shape[2] or \
                self.R.shape[1] != self.A.shape[1]:
            raise BundleError(f"inconsistent factor shapes A{self.A.shape} "
                              f"R{self.R.shape}")

    @property
    def n(self) -> int:
        return self.A.shape[0]

    @property
    def k(self) -> int:
        return self.A.shape[1]

    @property
    def m(self) -> int:
        return self.R.shape[0]

    def digest(self) -> str:
        return _digest(self.A, self.R)

    # -- construction -----------------------------------------------------

    @classmethod
    def from_sweep(cls, res, *, entities=None, relations=None,
                   permutation=None, manifest=None,
                   meta: dict | None = None) -> "FactorBundle":
        """Package a RescalkResult's selected-k best factors: the
        member-median A and its regressed R (selection.reduce_k)."""
        kr = res.per_k[res.k_opt]
        info = {"k_opt": int(res.k_opt),
                "ks": [int(k) for k in np.asarray(res.ks).tolist()],
                "rel_err": float(np.asarray(res.rel_err)[
                    list(res.ks).index(res.k_opt)])}
        info.update(meta or {})
        return cls(A=kr.A_median, R=kr.R_regress, entities=entities,
                   relations=relations, permutation=permutation,
                   manifest=manifest, meta=info)

    # -- persistence ------------------------------------------------------

    def save(self, bundle_dir: str) -> str:
        os.makedirs(bundle_dir, exist_ok=True)
        arrays = {"A": self.A, "R": self.R}
        if self.permutation is not None:
            arrays["permutation"] = np.asarray(self.permutation)
        npz_path = os.path.join(bundle_dir, ARRAYS_NAME)
        tmp = npz_path + ".tmp"
        with open(tmp, "wb") as f:
            np.savez(f, **arrays)
        os.replace(tmp, npz_path)
        doc = {"format_version": FORMAT_VERSION,
               "n": self.n, "m": self.m, "k": self.k,
               "digest": self.digest(),
               "arrays": ARRAYS_NAME,
               "entities": self.entities,
               "relations": self.relations,
               "manifest": self.manifest,
               "meta": self.meta}
        atomic_json_dump(os.path.join(bundle_dir, MANIFEST_NAME), doc,
                         indent=1, default=str)
        return bundle_dir

    @classmethod
    def load(cls, bundle_dir: str, *,
             check_digest: bool = True) -> "FactorBundle":
        man_path = os.path.join(bundle_dir, MANIFEST_NAME)
        try:
            with open(man_path) as f:
                doc = json.load(f)
        except OSError as ex:
            raise BundleError(f"cannot read {man_path}: "
                              f"{ex.strerror or ex}")
        except json.JSONDecodeError as ex:
            raise BundleError(f"{man_path} is not valid JSON: {ex}")
        if doc.get("format_version") != FORMAT_VERSION:
            raise BundleError(f"{man_path}: format_version "
                              f"{doc.get('format_version')!r}, this build "
                              f"reads {FORMAT_VERSION}")
        npz_path = os.path.join(bundle_dir, doc.get("arrays", ARRAYS_NAME))
        try:
            data = np.load(npz_path)
        except OSError as ex:
            raise BundleError(f"cannot read {npz_path}: "
                              f"{ex.strerror or ex}")
        except Exception as ex:
            raise BundleError(f"{npz_path} is not a readable npz: {ex}")
        with data:
            if "A" not in data.files or "R" not in data.files:
                raise BundleError(f"{npz_path}: needs 'A' and 'R' arrays, "
                                  f"has {sorted(data.files)}")
            A, R = data["A"], data["R"]
            perm = data["permutation"] if "permutation" in data.files \
                else None
        bundle = cls(A=A, R=R, entities=doc.get("entities"),
                     relations=doc.get("relations"), permutation=perm,
                     manifest=doc.get("manifest"),
                     meta=doc.get("meta") or {})
        for field, want in (("n", bundle.n), ("m", bundle.m),
                            ("k", bundle.k)):
            if doc.get(field) != want:
                raise BundleError(f"{man_path}: {field}={doc.get(field)!r} "
                                  f"but {npz_path} holds {field}={want}")
        if check_digest and doc.get("digest") != bundle.digest():
            raise BundleError(f"{bundle_dir}: factor digest mismatch — "
                              f"manifest {doc.get('digest')!r} vs arrays "
                              f"{bundle.digest()!r}")
        return bundle
