"""repro.serve — online KG-completion from swept RESCAL factors.

The sweep's whole point of output is the winning (A, R) pair; this package
turns it into a product:

  bundle.py   FactorBundle — the versioned on-disk factor artifact that
              `rescalk_run` persists next to the report (A, R, vocab,
              permutation, training-manifest digest) and `launch/serve`
              loads
  engine.py   ServeEngine — query micro-batching into ONE compiled shape
              (pad-and-mask), hot-head LRU caching for zipf-skewed query
              streams, and scoring through the `score_topk` panel kernel
              (the (batch, n) score matrix never materializes)
"""
from .bundle import FORMAT_VERSION, BundleError, FactorBundle
from .engine import (Query, ServeConfig, ServeEngine, parse_queries_tsv,
                     random_queries)

__all__ = ["BundleError", "FORMAT_VERSION", "FactorBundle", "Query",
           "ServeConfig", "ServeEngine", "parse_queries_tsv",
           "random_queries"]
