"""ServeEngine — online link-prediction over a FactorBundle.

Request path (every stage wears an obs span, zero-cost when untraced):

  1. **cache probe** — queries are keyed (mode, anchor, rel); a hot-head
     LRU absorbs the head of zipf-skewed streams (the same skew the
     virtual zipf patterns model) so repeated heads never reach the device
  2. **micro-batching** — cache misses are deduplicated and padded to ONE
     fixed compiled batch shape (`ServeConfig.batch`); the pad rows are
     real (anchor 0, relation 0) but their results are dropped on the
     host, so any query count reuses the same compiled program — program
     count stays O(1), not O(distinct batch sizes)
  3. **scoring** — one jitted program gathers the anchors, orients R per
     query (`(s, r, ?)` uses R[r], `(?, r, o)` uses R[r]^T — the mode is
     *data*, a boolean lane, so both directions share the program), and
     ranks via `kernels.ops.score_topk`, which never materializes the
     (batch, n) score matrix (Pallas kernel on TPU, panelized jnp stream
     on CPU, per the engine's KernelPolicy)

Scores are `A[anchor] @ R_q @ A^T` rows reduced to (topk,) — descending,
missing slots (topk > n) as (-inf, -1).

Robustness (ISSUE 10): `ServeConfig.deadline` bounds each request's
wall-clock and `ServeConfig.admit` bounds how many uncached keys one
request may score; work past either bound is *shed* — those queries get
the (-inf, -1) sentinel with ``shed=True``, a `serve/shed` event, and a
counter in `stats()` — so an overloaded engine degrades by answering
less, never by queueing unboundedly.  `reload()` hot-swaps a new
digest-validated FactorBundle atomically (factors + cache swap only
after the bundle fully validates, so a corrupt push can never leave the
engine half-updated).
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.kernels.policy import KernelPolicy
from repro.obs import trace as obs
from repro.resilience import faults

from .bundle import FactorBundle

MODES = ("sro", "sor")


class Query(NamedTuple):
    mode: str          # "sro" = (s, r, ?) | "sor" = (?, r, o)
    anchor: int        # subject id (sro) or object id (sor)
    rel: int


class QueryResult(NamedTuple):
    scores: np.ndarray     # (topk,) f32, descending
    indices: np.ndarray    # (topk,) i32, -1 past n
    cached: bool
    shed: bool = False     # dropped under deadline/admission pressure


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    topk: int = 10
    batch: int = 32              # the ONE compiled micro-batch width
    cache_entries: int = 4096    # 0 disables the hot-head LRU
    pn: int | None = None        # score_topk panel length (None = default)
    kernel: KernelPolicy = KernelPolicy()
    deadline: float | None = None  # per-request wall-clock budget, seconds
    admit: int | None = None     # max uncached keys scored per request


class ServeEngine:
    """Stateful server over one FactorBundle.  Not thread-safe by design
    (one engine per worker; the jitted scorer itself is reentrant)."""

    def __init__(self, bundle: FactorBundle, cfg: ServeConfig | None = None):
        self.cfg = cfg = cfg or ServeConfig()
        self.bundle = bundle
        self.A = jnp.asarray(bundle.A, jnp.float32)
        self.R = jnp.asarray(bundle.R, jnp.float32)
        self.n, self.k = bundle.n, bundle.k
        self.m = bundle.m
        self._cache: OrderedDict[tuple, tuple] = OrderedDict()
        self.hits = self.misses = self.evictions = 0
        self.batches = 0
        self.sheds = self.reloads = 0

        topk, impl, pn = cfg.topk, cfg.kernel.impl, cfg.pn

        @jax.jit
        def _score(A, R, anchors, rels, is_sro):
            E = A[anchors]                                   # (b, k)
            Rq = R[rels]                                     # (b, k, k)
            Rq = jnp.where(is_sro[:, None, None], Rq,
                           jnp.swapaxes(Rq, 1, 2))
            V = jnp.einsum("bi,bij->bj", E, Rq)
            kw = {} if pn is None else {"pn": pn}
            return ops.score_topk(V, A, topk=topk, impl=impl, **kw)

        self._score = _score

    # -- cache ------------------------------------------------------------

    def _cache_get(self, entry):
        hit = self._cache.get(entry)
        if hit is not None:
            self._cache.move_to_end(entry)
        return hit

    def _cache_put(self, entry, value):
        if self.cfg.cache_entries <= 0:
            return
        self._cache[entry] = value
        self._cache.move_to_end(entry)
        while len(self._cache) > self.cfg.cache_entries:
            self._cache.popitem(last=False)
            self.evictions += 1

    # -- scoring ----------------------------------------------------------

    def _score_chunk(self, keys: list[tuple]) -> list[tuple]:
        """Score up to `batch` unique (mode, anchor, rel) keys through the
        one compiled program; pad rows are dropped host-side."""
        b = self.cfg.batch
        anchors = np.zeros(b, np.int32)
        rels = np.zeros(b, np.int32)
        is_sro = np.ones(b, bool)
        for j, (mode, anchor, rel) in enumerate(keys):
            anchors[j], rels[j], is_sro[j] = anchor, rel, mode == "sro"
        with obs.span("serve/score", batch=b, live=len(keys)):
            s, i = self._score(self.A, self.R, jnp.asarray(anchors),
                               jnp.asarray(rels), jnp.asarray(is_sro))
            s, i = np.asarray(s), np.asarray(i)       # blocks until ready
        self.batches += 1
        return [(s[j], i[j]) for j in range(len(keys))]

    def _shed_sentinel(self) -> tuple[np.ndarray, np.ndarray]:
        return (np.full(self.cfg.topk, -np.inf, np.float32),
                np.full(self.cfg.topk, -1, np.int32))

    def query(self, queries: Sequence[Query]) -> list[QueryResult]:
        """Answer a request of queries; any count compiles ZERO new
        programs after the first batch (pad-and-mask to cfg.batch).

        Overload degrades, never queues: uncached keys past cfg.admit —
        and chunks that would start after cfg.deadline has elapsed — are
        shed with the (-inf, -1) sentinel and ``shed=True``."""
        with obs.span("serve/request", n=len(queries)):
            faults.fire("serve/request", n=len(queries))
            t0 = time.perf_counter()
            results: list[QueryResult | None] = [None] * len(queries)
            pending: OrderedDict[tuple, list[int]] = OrderedDict()
            for i, q in enumerate(queries):
                if q.mode not in MODES:
                    raise ValueError(f"query mode must be one of {MODES}, "
                                     f"got {q.mode!r}")
                if not (0 <= q.anchor < self.n and 0 <= q.rel < self.m):
                    raise ValueError(f"query out of range for (n={self.n}, "
                                     f"m={self.m}): {q}")
                key = (q.mode, int(q.anchor), int(q.rel))
                hit = self._cache_get(key)
                if hit is not None:
                    self.hits += 1
                    results[i] = QueryResult(hit[0], hit[1], True)
                else:
                    self.misses += 1
                    pending.setdefault(key, []).append(i)
            uniq = list(pending)
            shed_keys: list[tuple] = []
            admit = self.cfg.admit
            if admit is not None and len(uniq) > admit:
                uniq, shed_keys = uniq[:admit], uniq[admit:]
            for c0 in range(0, len(uniq), self.cfg.batch):
                if (self.cfg.deadline is not None
                        and time.perf_counter() - t0 > self.cfg.deadline):
                    shed_keys.extend(uniq[c0:])
                    break
                chunk = uniq[c0:c0 + self.cfg.batch]
                for key, out in zip(chunk, self._score_chunk(chunk)):
                    self._cache_put(key, out)
                    for i in pending[key]:
                        results[i] = QueryResult(out[0], out[1], False)
            if shed_keys:
                sent = self._shed_sentinel()
                n_shed = 0
                for key in shed_keys:
                    for i in pending[key]:
                        results[i] = QueryResult(sent[0], sent[1], False,
                                                 True)
                        n_shed += 1
                self.sheds += n_shed
                obs.event("serve/shed", queries=n_shed,
                          keys=len(shed_keys),
                          elapsed=round(time.perf_counter() - t0, 6))
            obs.event("serve/cache", hits=self.hits, misses=self.misses,
                      evictions=self.evictions, size=len(self._cache))
        return results      # type: ignore[return-value]

    # -- hot reload --------------------------------------------------------

    def reload(self, bundle_dir: str) -> FactorBundle:
        """Hot-swap the factors from a new on-disk bundle.  The load is
        digest-validated (FactorBundle.load re-derives the sha1 and
        raises BundleError on mismatch) and the swap is atomic from the
        engine's point of view: factors, dims, and cache all change only
        after the new bundle fully validates — a corrupt push leaves the
        engine serving the old factors untouched."""
        with obs.span("serve/reload", path=bundle_dir):
            new = FactorBundle.load(bundle_dir)             # may raise
            A = jnp.asarray(new.A, jnp.float32)
            R = jnp.asarray(new.R, jnp.float32)
            # commit point — nothing before this mutated engine state
            self.bundle, self.A, self.R = new, A, R
            self.n, self.k, self.m = new.n, new.k, new.m
            self._cache.clear()
            self.reloads += 1
            obs.event("serve/reload", digest=new.digest(), n=new.n,
                      k=new.k, m=new.m)
        return new

    def stats(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "evictions": self.evictions, "batches": self.batches,
                "sheds": self.sheds, "reloads": self.reloads,
                "cache_size": len(self._cache)}


# -- query sources --------------------------------------------------------

def random_queries(n: int, m: int, count: int, *, skew: float = 1.1,
                   seed: int = 0, mode: str = "mixed") -> list[Query]:
    """A zipf-skewed query stream (rank r anchor ~ r^-skew, the shape the
    hot-head cache exists for).  mode: sro | sor | mixed."""
    rng = np.random.default_rng(seed)
    anchors = (rng.zipf(max(skew, 1.01), size=count) - 1) % n
    rels = rng.integers(0, m, size=count)
    if mode == "mixed":
        modes = np.where(rng.random(count) < 0.5, "sro", "sor")
    elif mode in MODES:
        modes = np.full(count, mode)
    else:
        raise ValueError(f"mode must be sro|sor|mixed, got {mode!r}")
    return [Query(str(md), int(a), int(r))
            for md, a, r in zip(modes, anchors, rels)]


def parse_queries_tsv(path: str, *, entities: list[str] | None = None,
                      relations: list[str] | None = None) -> list[Query]:
    """Parse `s<TAB>r<TAB>?` / `?<TAB>r<TAB>o` lines into queries.  Names
    resolve through the bundle vocab when present; otherwise every field
    must already be an integer id."""
    ent_id = {name: i for i, name in enumerate(entities or [])}
    rel_id = {name: i for i, name in enumerate(relations or [])}

    def _id(tok: str, table: dict, what: str, lineno: int) -> int:
        if tok in table:
            return table[tok]
        try:
            return int(tok)
        except ValueError:
            raise ValueError(f"{path}:{lineno}: unknown {what} {tok!r} "
                             f"(not in bundle vocab, not an id)")

    queries = []
    with open(path) as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line or line.startswith("#"):
                continue
            parts = line.split("\t")
            if len(parts) != 3 or (parts[0] == "?") == (parts[2] == "?"):
                raise ValueError(f"{path}:{lineno}: want "
                                 f"'s<TAB>r<TAB>?' or '?<TAB>r<TAB>o', "
                                 f"got {line!r}")
            s, r, o = parts
            rel = _id(r, rel_id, "relation", lineno)
            if o == "?":
                queries.append(Query("sro", _id(s, ent_id, "entity",
                                                lineno), rel))
            else:
                queries.append(Query("sor", _id(o, ent_id, "entity",
                                                lineno), rel))
    return queries
